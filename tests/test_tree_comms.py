"""Distributed tree-growth communication modes (ISSUE 7 tentpole).

``HistogramTrees`` grows the same greedy depth-d tree under three wire
protocols: ``coreset`` (ship c weighted examples — the paper's
BoostAttempt payload), ``histogram`` (merge per-node weighted
histograms by sum), and ``voting`` (top-k split proposals per node, a
deterministic election, merged histograms on the elected columns
only).  Pinned here:

* the three engines (host loop / batched / mesh-sharded) are
  bit-identical WITHIN each mode — hypotheses, rounds, ledgers;
* the ledger's new ``bits_histograms`` / ``bits_votes`` accounting
  equals the payloads measured at the sharded engine's collectives
  (``validate_ledger``), and ``theorem_41_bound`` stays an upper bound;
* the election is deterministic (vote count, lowest feature wins ties)
  and the two merge modes pick gain-equivalent splits;
* the scheduler partitions mixed comm-mode requests into separate
  compile buckets and serves each bit-identically to its one-shot run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (batched, classify, ledger, scenarios,
                        sharded_batched, weak)
from repro.core.types import BoostConfig
from repro.launch import scheduler as S
from repro.weak_tree.trees import HistogramTrees

K = 4
CFG = BoostConfig(k=K, coreset_size=64, domain_size=1 << 12,
                  opt_budget=16, deterministic_coreset=False)
MODES = ("coreset", "histogram", "voting")


def _cls(mode, topk=1):
    return weak.make_class("tree", num_features=4, tree_depth=2,
                           tree_bins=8, tree_comm_mode=mode,
                           tree_vote_topk=topk)


def _batch(cls, B=2, m=256, seed0=31):
    spec = scenarios.ScenarioSpec(name="xor", noise=2)
    x, y, ts = scenarios.make_scenario_batch(cls, B, m, K, spec,
                                             seed0=seed0)
    keys = jax.random.split(jax.random.key(11), B)
    return x, y, keys, ts


# ---------------------------------------------------------------------------
# Engine parity + ledger ≡ payload, per mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_three_engines_bit_identical_per_mode(mode):
    cls = _cls(mode)
    x, y, keys, ts = _batch(cls)
    bat = batched.run_accurately_classify_batched(x, y, keys, CFG, cls)
    sh = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, CFG, cls)
    assert bool(bat.ok.all()) and bool(sh.ok.all())
    for b in range(2):
        host = classify.run_accurately_classify(
            jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], CFG, cls)
        assert host.attempts == int(bat.attempts[b]) \
            == int(sh.attempts[b])
        np.testing.assert_array_equal(
            np.asarray(host.hypotheses)[:host.rounds],
            np.asarray(bat.hypotheses[b])[:int(bat.rounds[b])])
        np.testing.assert_array_equal(
            np.asarray(host.hypotheses)[:host.rounds],
            sh.hypotheses[b][:int(sh.rounds[b])])
        for f in ("bits_coresets", "bits_weight_sums",
                  "bits_hypotheses", "bits_control", "bits_dispute",
                  "bits_histograms", "bits_votes"):
            assert getattr(host.ledger, f) \
                == getattr(bat.ledger(b), f) \
                == getattr(sh.ledger(b), f), f
        sh.validate_ledger(b)
        # the served classifier still meets the protocol guarantee
        errs = int(weak.empirical_errors(
            sh.classifier(b)(jnp.asarray(ts[b].flat_x)),
            jnp.asarray(ts[b].flat_y)))
        assert errs <= scenarios.planted_errors(ts[b])


@pytest.mark.parametrize("mode", ("histogram", "voting"))
def test_mode_payload_accounting_and_bound(mode):
    """Distributed modes charge histogram/vote payloads every wire
    round and coreset examples ONLY on the stuck round; the Theorem-4.1
    style bound extended with the mode payload stays an upper bound."""
    cls = _cls(mode)
    x, y, keys, ts = _batch(cls)
    sh = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, CFG, cls)
    hist_pp = ledger.hist_scalars_per_player(cls)
    vote_pp = ledger.vote_entries_per_player(cls)
    assert hist_pp > 0
    assert (vote_pp > 0) == (mode == "voting")
    for b in range(2):
        led = sh.ledger(b)
        assert led.bits_histograms > 0
        assert (led.bits_votes > 0) == (mode == "voting")
        # coresets only ship on stuck rounds in distributed modes
        n_att = int(sh.attempts[b])
        stuck_rounds = int(np.sum(np.asarray(sh.hist_stuck)[b, :n_att]))
        if stuck_rounds == 0:
            assert led.bits_coresets == 0
        m_task = ts[b].flat_y.shape[0]
        bound = ledger.theorem_41_bound(CFG, cls, m_task,
                                        scenarios.planted_errors(ts[b]))
        assert led.total_bits <= bound, (led.total_bits, bound)


def test_voting_moves_fewer_bits_than_histogram_than_coreset():
    """At benchmark sizing (c ≫ histogram cells ≫ vote entries) the
    per-wire-round payload ordering is strict — checked on the ledger
    of identical runs, not just the closed-form constants."""
    cfg = BoostConfig(k=K, coreset_size=512, domain_size=1 << 24,
                      opt_budget=16, deterministic_coreset=False)
    bits = {}
    for mode in MODES:
        cls = weak.make_class("tree", num_features=8, tree_depth=2,
                              tree_bins=8, tree_comm_mode=mode,
                              tree_vote_topk=1)
        x, y, keys, _ = _batch(cls, m=256, seed0=5)
        sh = sharded_batched.run_accurately_classify_sharded(
            x, y, keys, cfg, cls)
        assert bool(sh.ok.all())
        sh.validate_ledger(0)
        bits[mode] = sum(sh.ledger(b).total_bits for b in range(2))
    assert bits["voting"] < bits["histogram"] < bits["coreset"], bits


# ---------------------------------------------------------------------------
# erm_players semantics
# ---------------------------------------------------------------------------

def test_erm_players_identity_gather_matches_erm_host():
    """Single player (k=1): the merged-histogram grower IS the local
    greedy grower — erm_players with the identity gather must equal
    plain erm bit-for-bit (same kernels, same reduction order)."""
    cls = HistogramTrees(num_features=4, depth=2, bins=8,
                         comm_mode="histogram")
    rng = np.random.default_rng(3)
    c = 96
    cx = jnp.asarray(rng.random((1, c, 4)), jnp.float32)
    cy = jnp.asarray(rng.choice([-1, 1], (1, c)), jnp.float32)
    w = jnp.ones((1,), jnp.float32)
    params, loss = cls.erm_players(cx, cy, w)
    ref_params, ref_loss = cls.erm(cx[0], cy[0],
                                   jnp.ones((c,), jnp.float32) / c)
    np.testing.assert_array_equal(np.asarray(params),
                                  np.asarray(ref_params))
    # losses agree up to the weight normalisation: erm_players takes
    # UNNORMALISED per-point weights (pw=1 each ⇒ total mass c), erm a
    # distribution summing to 1 — same split, c× the reported loss
    assert np.isclose(float(loss), float(ref_loss) * c)


def test_voting_election_deterministic_lowest_feature_tie():
    """With every player proposing the same single feature, the
    election must elect it first; remaining seats break ties toward the
    LOWEST feature index (the rank = votes·F + (F−1−f) pinning)."""
    cls = HistogramTrees(num_features=4, depth=1, bins=8,
                         comm_mode="voting", vote_topk=1)
    rng = np.random.default_rng(0)
    kp, c = 3, 64
    # feature 2 perfectly labels; others are noise → every player
    # proposes f=2, seats = 2·topk = 2, second seat = lowest index 0
    cx = rng.random((kp, c, 4)).astype(np.float32)
    cy = np.where(cx[..., 2] >= 0.5, 1.0, -1.0).astype(np.float32)
    params, loss = cls.erm_players(jnp.asarray(cx), jnp.asarray(cy),
                                   jnp.ones((kp,), jnp.float32))
    feats = np.asarray(params)[1:2].astype(int)   # depth 1: one node
    assert feats[0] == 2
    assert float(loss) == 0.0
    # determinism: same inputs, same bits
    p2, l2 = cls.erm_players(jnp.asarray(cx), jnp.asarray(cy),
                             jnp.ones((kp,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(params), np.asarray(p2))


def test_comm_mode_validation_and_elected():
    with pytest.raises(ValueError, match="comm_mode"):
        HistogramTrees(num_features=4, depth=2, bins=8,
                       comm_mode="telepathy")
    with pytest.raises(ValueError, match="vote_topk"):
        HistogramTrees(num_features=4, depth=2, bins=8,
                       comm_mode="voting", vote_topk=0)
    t = HistogramTrees(num_features=4, depth=2, bins=8,
                       comm_mode="voting", vote_topk=1)
    assert t.elected == 2
    wide = HistogramTrees(num_features=4, depth=2, bins=8,
                          comm_mode="voting", vote_topk=8)
    assert wide.elected == 4          # clamped to F


# ---------------------------------------------------------------------------
# Scheduler integration: comm modes are engine statics
# ---------------------------------------------------------------------------

def test_scheduler_partitions_mixed_comm_modes():
    """Same-shape requests with different comm modes must land in
    different compile buckets (CompatKey embeds the class) and each
    serve bit-identically to its own one-shot engine run."""
    lattice = S.BucketLattice(b_sizes=(2,), mloc_sizes=(64,))
    common = dict(m=128, k=2, noise=1, clsname="tree", domain=1 << 12,
                  num_features=4, tree_depth=2, tree_bins=8,
                  tree_vote_topk=1, coreset_size=48, opt_budget=8,
                  scenario="xor")
    reqs = [S.Request(rid=i, seed=40 + i % 2, tree_comm_mode=mode,
                      **common)
            for i, mode in enumerate(("coreset", "coreset",
                                      "histogram", "histogram",
                                      "voting", "voting"))]
    keys = {S.CompatKey.of(r) for r in reqs}
    assert len(keys) == 3             # one bucket per comm mode
    sched = S.BoostScheduler(lattice=lattice, policy="pack")
    done = sched.run_stream(reqs)
    assert len(done) == 6
    assert {c.request.tree_comm_mode for c in done} \
        == {"coreset", "histogram", "voting"}
    for c in done:
        one = sched.one_shot(c.request)
        np.testing.assert_array_equal(c.result.hypotheses[c.lane],
                                      one.hypotheses[0])
        assert int(c.result.attempts[c.lane]) == int(one.attempts[0])
